"""End-to-end driver (deliverable b): serve a small model to a batched
30-device fleet through the full HAT stack and compare all four frameworks.

    PYTHONPATH=src python examples/serve_cluster.py                 # statistical fleet
    PYTHONPATH=src python examples/serve_cluster.py --real          # real JAX models
    PYTHONPATH=src python examples/serve_cluster.py --engine        # session API demo
    PYTHONPATH=src python examples/serve_cluster.py --net           # real processes

The default mode runs the paper's §4.2 experiment shape: Poisson arrivals
over 30 heterogeneous Jetson-class devices, SpecBench-like prompt lengths,
continuous batching in the cloud; prints the Fig. 6/8-style comparison.
``--engine`` demonstrates the session API: DeviceClient sessions streaming
tokens through a CloudServer over wire frames — no hand-rolled framing.
``--net`` runs the real thing: 1 cloud service process + N device worker
processes exchanging frames over localhost TCP, wall-clock TTFT/TBT and a
merged multi-process Chrome trace.
"""
import argparse
import json

import numpy as np


def _dump_trace(tracer, path, label):
    from repro.obs import validate_chrome_trace

    obj = tracer.to_chrome_trace()
    validate_chrome_trace(obj)
    tracer.dump(path)
    print(f"{label}: {len(obj['traceEvents'])} trace events -> {path} "
          "(open in chrome://tracing or ui.perfetto.dev)")


def fleet_comparison(args):
    from repro.data import SPECBENCH, sample_workload
    from repro.obs import Tracer
    from repro.serving import ServeConfig, SimulatorRuntime

    rng = np.random.default_rng(0)
    reqs = sample_workload(SPECBENCH, rng, n_requests=args.requests,
                           rate_per_s=args.rate, with_tokens=args.real)

    d_model = 4096
    if args.real:
        import jax

        from repro.configs import get_config
        from repro.core import init_adapter, make_distill_step, split_model
        from repro.data import markov_corpus, token_batches
        from repro.models import Model
        from repro.serving import RealBackend, init_medusa
        from repro.training import AdamW, train_loop
        import jax.numpy as jnp

        cfg = get_config(args.arch).reduced()
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        corpus = markov_corpus(np.random.default_rng(1), cfg.vocab_size, 20_000)
        params, _ = train_loop(model, params, AdamW(lr=3e-3),
                               token_batches(np.random.default_rng(2), corpus, 8, 32),
                               max_steps=50, log_every=0)
        split = split_model(cfg, params)
        adapter, _ = init_adapter(cfg, jax.random.PRNGKey(7))
        opt = AdamW(lr=1e-3)
        dstep = make_distill_step(split, model, params, opt)
        ost = opt.init(adapter)
        for i, b in zip(range(60), token_batches(np.random.default_rng(3), corpus, 8, 32)):
            adapter, ost, _ = dstep(adapter, ost, jnp.asarray(b["tokens"][:, :32]))
        medusa, _ = init_medusa(cfg, jax.random.PRNGKey(8))
        d_model = cfg.d_model

        def make_backend(fw):
            return RealBackend(
                split,
                adapter_params=adapter if fw == "hat" else None,
                medusa_params=medusa if fw == "u-medusa" else None,
                max_len=512,
                wire_codec=args.wire_codec,
            )
    else:
        def make_backend(fw):
            return None

    from repro.wire import get_codec

    bpt = get_codec(args.wire_codec).bytes_per_token(d_model)
    print(f"wire codec {args.wire_codec}: {bpt:.0f} B/token on the link")
    print(f"{'framework':12s} {'TTFT(ms)':>10s} {'TBT(ms)':>9s} "
          f"{'accept':>7s} {'cloud(ms)':>12s}")
    for fw in ("u-shape", "u-sarathi", "u-medusa", "hat"):
        config = ServeConfig.from_framework(
            fw, wire_codec=args.wire_codec, d_model=d_model,
            pipeline_len=args.pipeline_len,
        )
        # flight-record the HAT run when asked: every hop of every request
        # lands in one Chrome trace on the simulator's virtual clock
        tracer = Tracer() if args.trace_out and fw == "hat" else None
        runtime = SimulatorRuntime(config, backend=make_backend(fw),
                                   rng=np.random.default_rng(9),
                                   tracer=tracer)
        m = runtime.serve(reqs)
        s = m.summary()
        print(f"{fw:12s} {s['ttft_mean_ms']:10.1f} {s['tbt_mean_ms']:9.1f} "
              f"{s['accept_length']:7.2f} "
              f"{s['cloud_delay_mean_ms']:6.1f}±{s['cloud_delay_std_ms']:.1f}")
        if tracer is not None:
            _dump_trace(tracer, args.trace_out, f"{fw} fleet trace")


def engine_demo(args):
    """The session API, end to end: DeviceClient sessions stream tokens
    through a CloudServer (slot-batched CloudEngine) — chunked prefill,
    per-round verification, every hidden-state hop a ``--wire-codec``
    frame.  No hand-rolled frame encoding anywhere: the client owns it.

    Part two runs the same sessions through the *concurrent* EngineRuntime:
    the scheduler interleaves all sessions' coroutines on a shared virtual
    clock, so one engine step batches chunks/strips of several requests —
    compare its steps × batched-tokens profile against the sequential
    per-request loop above."""
    import jax

    from repro.configs import get_config
    from repro.core import split_model
    from repro.data import RequestSpec
    from repro.models import Model
    from repro.serving import (
        CloudServer,
        DeviceClient,
        EngineRuntime,
        LoopbackTransport,
        ServeConfig,
    )
    from repro.wire import get_codec

    cfg = get_config(args.arch).reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    split = split_model(cfg, params)

    server = CloudServer(split, n_slots=4, max_len=128, max_batch_tokens=48,
                         wire_codec=args.wire_codec)
    transport = LoopbackTransport(server)
    client = DeviceClient(split, transport, wire_codec=args.wire_codec,
                          max_len=128, fixed_chunk=16)
    codec = get_codec(args.wire_codec)
    rng = np.random.default_rng(0)

    print(f"3 DeviceClient sessions, chunked prefill via {codec.name} frames")
    for rid, plen in [(0, 40), (1, 25), (2, 33)]:
        prompt = rng.integers(3, cfg.vocab_size, size=plen).astype(np.int32)
        toks = list(client.generate(prompt, max_new_tokens=4, req_id=rid))
        print(f"  req {rid}: prompt {plen} tokens -> generated {toks}")
    eng = server.engine
    print(f"engine ran {eng.steps} batched steps; "
          f"batched tokens per step: {eng.batched_token_history}")
    print(f"wire: {eng.wire_bytes_in} B up, {eng.wire_bytes_out} B down "
          f"({codec.bytes_per_token(cfg.d_model):.0f} B/token payload; "
          f"fp16 would be {2 * cfg.d_model} B/token)")

    # ---- part two: the same workload, concurrently scheduled ---------------
    reqs = [
        RequestSpec(req_id=i, device_id=i, arrival_s=0.02 * i, prompt_len=pl,
                    max_new_tokens=4,
                    prompt=rng.integers(3, cfg.vocab_size, pl).astype(np.int32))
        for i, pl in enumerate([40, 25, 33])
    ]
    config = ServeConfig.u_shape(wire_codec=args.wire_codec, n_devices=3,
                                 dynamic_chunks=False, fixed_chunk=16)
    tracer = None
    if args.trace_out:
        from repro.obs import Tracer
        tracer = Tracer()
    runtime = EngineRuntime(config, split, rng=np.random.default_rng(1),
                            n_slots=4, max_len=128, concurrent=True,
                            tracer=tracer)
    m = runtime.serve(reqs)
    s = m.summary()
    for r in m.requests:
        print(f"  [concurrent] req {r.req_id}: generated {r.generated}")
    print(f"concurrent runtime: {s['cloud_steps']} batched steps, "
          f"{s['batch_tokens_per_step_mean']:.1f} tokens/step, "
          f"{s['engine_jit_compiles']} step variants compiled, "
          f"peak {runtime.server.engine.kv.peak_active} sessions in flight")
    if tracer is not None:
        bd = s.get("ttft_breakdown_ms", {})
        print("mean TTFT breakdown: "
              + ", ".join(f"{k} {v:.2f}ms" for k, v in bd.items()))
        _dump_trace(tracer, args.trace_out, "engine trace")


def net_demo(args):
    """Real multi-process serving: spawn 1 cloud + N device processes on
    localhost TCP and report measured (not simulated) latency.  The token
    streams are deterministic in (arch, seed), so the same workload served
    through an in-process loopback must match byte for byte — which is
    exactly what ``benchmarks/bench_engine.py --net tcp`` asserts."""
    from repro.net import run_cluster

    n_devices = 2
    result = run_cluster(
        args.arch,
        n_devices=n_devices,
        requests_per_device=max(1, args.requests // n_devices),
        wire_codec=args.wire_codec,
        workdir=args.net_workdir,
    )
    print(f"{n_devices} device processes + 1 cloud process "
          f"({result['host']}:{result['port']}), "
          f"{result['n_requests']} requests over TCP")
    print(f"measured TTFT mean {result['ttft_mean_ms']:.1f}ms "
          f"p90 {result['ttft_p90_ms']:.1f}ms, "
          f"TBT mean {result['tbt_mean_ms']:.1f}ms")
    print(f"wire: {result['bytes_up']} B up, {result['bytes_down']} B down")
    if result["merged_trace"]:
        print(f"merged cross-process trace -> {result['merged_trace']} "
              "(open in chrome://tracing or ui.perfetto.dev)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=150)
    ap.add_argument("--rate", type=float, default=6.0)
    ap.add_argument("--pipeline-len", type=int, default=4)
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--real", action="store_true")
    ap.add_argument("--engine", action="store_true")
    ap.add_argument("--net", action="store_true",
                    help="real multi-process serving over localhost TCP "
                         "(1 cloud + 2 device processes)")
    ap.add_argument("--net-workdir", default=None,
                    help="with --net: directory for logs/results/traces")
    ap.add_argument("--trace-out", default=None,
                    help="dump a Chrome-trace JSON of the run "
                         "(HAT fleet run, or the concurrent engine demo)")
    from repro.wire import CODECS

    ap.add_argument("--wire-codec", default="fp16", choices=sorted(CODECS),
                    help="hidden-state transport codec on the device-cloud wire")
    args = ap.parse_args()
    if args.net:
        args.requests = min(args.requests, 8)  # real processes: keep it a demo
        net_demo(args)
    elif args.engine:
        engine_demo(args)
    else:
        fleet_comparison(args)


if __name__ == "__main__":
    main()
