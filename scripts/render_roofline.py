"""Render EXPERIMENTS.md tables from reports/dryrun/*.json.

    PYTHONPATH=src python scripts/render_roofline.py [--mesh 16x16]
"""
import argparse
import glob
import json
import os


def fmt_s(x):
    if x >= 1.0:
        return f"{x:.2f}s"
    return f"{x*1e3:.1f}ms"


def gib(b):
    return f"{b/2**30:.2f}"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--dir", default="reports/dryrun")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    rows = []
    for f in sorted(glob.glob(os.path.join(args.dir, "*.json"))):
        rec = json.load(open(f))
        if rec.get("tag", "") != args.tag:
            continue
        if args.mesh and rec.get("mesh") != args.mesh:
            continue
        rows.append(rec)

    print("| arch | shape | mesh | status | compute | memory | collective | "
          "dominant | useful | HBM/chip (args+temp) |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        if r.get("skipped"):
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | SKIP ({r['reason'][:40]}…) "
                  f"| — | — | — | — | — | — |")
            continue
        if not r.get("ok"):
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | FAIL | — | — | — | — | — | — |")
            continue
        rf = r["roofline"]
        m = r.get("memory", {})
        hbm = (m.get("argument_size_in_bytes", 0) + m.get("temp_size_in_bytes", 0))
        print(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok "
            f"| {fmt_s(rf['compute_s'])} | {fmt_s(rf['memory_s'])} "
            f"| {fmt_s(rf['collective_s'])} | {rf['dominant']} "
            f"| {rf['useful_flops_ratio']:.2f} | {gib(hbm)} GiB |"
        )


if __name__ == "__main__":
    main()
