"""Summarize flight-recorder Chrome-trace JSONs in the terminal.

The trace itself opens in chrome://tracing or https://ui.perfetto.dev; this
script is the no-browser path: validate the schema, then print per-request
phase tables (where every millisecond of each request's TTFT window went)
and the longest individual spans.

Multiple trace files — e.g. the per-process dumps a ``repro.net`` cluster
writes (cloud service + each device worker) — are merged into one trace
with disjoint pids before rendering; ``--merge-out`` saves the merged
(validated) JSON for the browser.

    PYTHONPATH=src python scripts/render_trace.py bench_engine_trace.json
    PYTHONPATH=src python scripts/render_trace.py trace.json --top 20
    PYTHONPATH=src python scripts/render_trace.py out/cloud_trace.json \
        out/dev0_trace.json out/dev1_trace.json --merge-out out/merged.json

stdlib + repro.obs only — safe to run anywhere the repo runs.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from collections import defaultdict

from repro.obs import (
    MERGE_PID_STRIDE,
    PHASES,
    PID_VIRTUAL,
    TID_CLOUD,
    merge_chrome_traces,
    validate_chrome_trace,
)


def _spans(obj):
    for ev in obj["traceEvents"]:
        if ev.get("ph") == "X":
            yield ev


def _instants(obj):
    for ev in obj["traceEvents"]:
        if ev.get("ph") == "i":
            yield ev


def _is_virtual(pid: int) -> bool:
    # merged traces shift each input's pids by k * MERGE_PID_STRIDE while
    # preserving the pid role within each block
    return pid % MERGE_PID_STRIDE == PID_VIRTUAL


def phase_table(obj) -> dict:
    """(pid, tid) -> phase -> total ms, over the virtual-time request rows."""
    table: dict = defaultdict(lambda: defaultdict(float))
    for ev in _spans(obj):
        if not _is_virtual(ev["pid"]) or ev["tid"] == TID_CLOUD:
            continue
        phase = ev.get("args", {}).get("phase")
        if phase:
            table[(ev["pid"], ev["tid"])][phase] += ev["dur"] / 1e3
    return table


def load_traces(paths):
    """Load one trace, or merge several (labelled by filename stem) into a
    single validated object with disjoint pids."""
    objs = []
    for path in paths:
        with open(path) as f:
            objs.append(json.load(f))
    if len(objs) == 1:
        validate_chrome_trace(objs[0])
        return objs[0]
    labels = [os.path.splitext(os.path.basename(p))[0] for p in paths]
    if len(set(labels)) != len(labels):         # e.g. a/trace.json b/trace.json
        labels = [f"{i}:{lab}" for i, lab in enumerate(labels)]
    merged = merge_chrome_traces(objs, labels)
    validate_chrome_trace(merged)
    return merged


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("traces", nargs="+",
                    help="Chrome-trace JSON(s) (tracer.dump output); "
                         "several files are merged with disjoint pids")
    ap.add_argument("--top", type=int, default=10,
                    help="longest spans to list")
    ap.add_argument("--merge-out", default=None,
                    help="write the merged (validated) trace JSON here")
    args = ap.parse_args(argv)

    obj = load_traces(args.traces)
    if args.merge_out:
        with open(args.merge_out, "w") as f:
            json.dump(obj, f, indent=1)
        print(f"merged {len(args.traces)} traces -> {args.merge_out}")

    spans = list(_spans(obj))
    other = obj.get("otherData", {})
    name = args.traces[0] if len(args.traces) == 1 \
        else f"{len(args.traces)} merged traces"
    print(f"{name}: schema v{obj['schemaVersion']}, "
          f"{len(obj['traceEvents'])} events ({len(spans)} spans), "
          f"{other.get('droppedEvents', 0)} dropped")

    table = phase_table(obj)
    if table:
        cols = [p for p in PHASES if any(p in r for r in table.values())]
        header = "req".rjust(10) + "".join(c.rjust(12) for c in cols) \
            + "total ms".rjust(12)
        print("\nper-request phase attribution (ms):\n" + header)
        for pid, tid in sorted(table):
            row = table[(pid, tid)]
            proc = pid // MERGE_PID_STRIDE
            label = f"{proc}/{tid}" if len(args.traces) > 1 else str(tid)
            print(f"{label:>10s}"
                  + "".join(f"{row.get(c, 0.0):12.2f}" for c in cols)
                  + f"{sum(row.values()):12.2f}")

    longest = sorted(spans, key=lambda e: e["dur"], reverse=True)[: args.top]
    if longest:
        print(f"\ntop {len(longest)} spans by duration:")
        for ev in longest:
            where = ("cloud" if ev["tid"] == TID_CLOUD
                     else f"req {ev['tid']}" if _is_virtual(ev["pid"])
                     else "host")
            print(f"  {ev['dur'] / 1e3:10.2f} ms  {ev['name']:<16s} {where}")

    # fault-tolerance instants: the flight recorder marks every injected
    # fault, reconnect, resume, busy push-back, detach and grace expiry
    instants = defaultdict(int)
    for ev in _instants(obj):
        instants[ev["name"]] += 1
    if instants:
        print("\ninstant events: " + ", ".join(
            f"{n} x{instants[n]}" for n in sorted(instants)))

    hists = other.get("histograms", {})
    for name, h in hists.items():
        if h.get("count"):
            print(f"\nhistogram {name}: n={h['count']} mean={h['mean']:.1f} "
                  f"p50={h['p50']:.1f} p90={h['p90']:.1f} max={h['max']:.1f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
