"""Summarize a flight-recorder Chrome-trace JSON in the terminal.

The trace itself opens in chrome://tracing or https://ui.perfetto.dev; this
script is the no-browser path: validate the schema, then print per-request
phase tables (where every millisecond of each request's TTFT window went)
and the longest individual spans.

    PYTHONPATH=src python scripts/render_trace.py bench_engine_trace.json
    PYTHONPATH=src python scripts/render_trace.py trace.json --top 20

stdlib + repro.obs only — safe to run anywhere the repo runs.
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import defaultdict

from repro.obs import PHASES, PID_VIRTUAL, TID_CLOUD, validate_chrome_trace


def _spans(obj):
    for ev in obj["traceEvents"]:
        if ev.get("ph") == "X":
            yield ev


def phase_table(obj) -> dict:
    """tid -> phase -> total ms, over the virtual-time request rows."""
    table: dict = defaultdict(lambda: defaultdict(float))
    for ev in _spans(obj):
        if ev["pid"] != PID_VIRTUAL or ev["tid"] == TID_CLOUD:
            continue
        phase = ev.get("args", {}).get("phase")
        if phase:
            table[ev["tid"]][phase] += ev["dur"] / 1e3
    return table


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("trace", help="Chrome-trace JSON (tracer.dump output)")
    ap.add_argument("--top", type=int, default=10,
                    help="longest spans to list")
    args = ap.parse_args(argv)

    with open(args.trace) as f:
        obj = json.load(f)
    validate_chrome_trace(obj)

    spans = list(_spans(obj))
    other = obj.get("otherData", {})
    print(f"{args.trace}: schema v{obj['schemaVersion']}, "
          f"{len(obj['traceEvents'])} events ({len(spans)} spans), "
          f"{other.get('droppedEvents', 0)} dropped")

    table = phase_table(obj)
    if table:
        cols = [p for p in PHASES if any(p in r for r in table.values())]
        header = "req".rjust(6) + "".join(c.rjust(12) for c in cols) \
            + "total ms".rjust(12)
        print("\nper-request phase attribution (ms):\n" + header)
        for tid in sorted(table):
            row = table[tid]
            print(f"{tid:6d}"
                  + "".join(f"{row.get(c, 0.0):12.2f}" for c in cols)
                  + f"{sum(row.values()):12.2f}")

    longest = sorted(spans, key=lambda e: e["dur"], reverse=True)[: args.top]
    if longest:
        print(f"\ntop {len(longest)} spans by duration:")
        for ev in longest:
            where = ("cloud" if ev["tid"] == TID_CLOUD
                     else f"req {ev['tid']}" if ev["pid"] == PID_VIRTUAL
                     else "host")
            print(f"  {ev['dur'] / 1e3:10.2f} ms  {ev['name']:<16s} {where}")

    hists = other.get("histograms", {})
    for name, h in hists.items():
        if h.get("count"):
            print(f"\nhistogram {name}: n={h['count']} mean={h['mean']:.1f} "
                  f"p50={h['p50']:.1f} p90={h['p90']:.1f} max={h['max']:.1f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
